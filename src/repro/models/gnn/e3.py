"""Minimal E(3)-equivariant algebra for l_max <= 2, in the CARTESIAN basis.

Hardware adaptation (DESIGN.md): e3nn's spherical-irrep tensor products need
Clebsch-Gordan tables and per-irrep segmented einsums -- gather-heavy and
convention-sensitive.  For l <= 2 the same algebra is exactly expressible
with Cartesian tensors, where every coupling path is a dense einsum (MXU
friendly) and equivariance is manifest:

  l=0  <-> scalar s
  l=1  <-> vector v (3,)
  l=2  <-> traceless symmetric matrix t (3, 3)

Features: dict {"s": (N, C, 1)? -> (N, C), "v": (N, C, 3), "t": (N, C, 3, 3)}.
Coupling paths used (a complete generating set for l<=2):
  s*s->s   v.v->s    t:t->s
  s*v->v   v x v->v  t@v->v
  s*t->t   v(x)v->t  sym(t@t)->t  v(x)t-ish via t@v paths
Radial envelopes weight each path per channel (NequIP-style).

`spherical` helpers give Y1 = r_hat and Y2 = r_hat r_hat^T - I/3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

I3 = jnp.eye(3)


def traceless_sym(m):
    """Project (..., 3, 3) onto traceless symmetric part (the l=2 subspace)."""
    s = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    return s - tr * I3 / 3.0


def sph(r):
    """r: (..., 3) displacement -> (rhat (...,3), Y2 (...,3,3), d (...,))."""
    d = jnp.linalg.norm(r, axis=-1)
    rhat = r / jnp.maximum(d, 1e-9)[..., None]
    y2 = rhat[..., :, None] * rhat[..., None, :] - I3 / 3.0
    return rhat, y2, d


def bessel_basis(d, n_rbf: int, cutoff: float):
    """Radial Bessel basis sin(n pi d / rc) / d with polynomial envelope
    (NequIP / DimeNet standard)."""
    dn = jnp.clip(d / cutoff, 1e-6, 1.0)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rb = jnp.sin(jnp.pi * n * dn[..., None]) / dn[..., None]
    # p=6 polynomial cutoff envelope
    env = 1 - 28 * dn**6 + 48 * dn**7 - 21 * dn**8
    return rb * env[..., None], env


# ----------------------------------------------------------------------------
# tensor-product paths: (features of j) x (edge harmonics), channelwise
# weighted by radial coefficients w[...] of shape (E, C)
# ----------------------------------------------------------------------------

N_PATHS = 10


def edge_tensor_product(feat_j, rhat, y2, w):
    """feat_j: {"s": (E, C), "v": (E, C, 3), "t": (E, C, 3, 3)};
    rhat (E, 3), y2 (E, 3, 3); w (E, C, N_PATHS) radial path weights.
    Returns message dict with the same structure."""
    s, v, t = feat_j["s"], feat_j["v"], feat_j["t"]
    r1 = rhat[:, None, :]                      # (E, 1, 3)
    Y2 = y2[:, None, :, :]                     # (E, 1, 3, 3)

    out_s = (w[..., 0] * s
             + w[..., 1] * jnp.einsum("eci,ei->ec", v, rhat)
             + w[..., 2] * jnp.einsum("ecij,eij->ec", t, y2))
    # matmul forms (not einsum) for the t-contractions: identical math, but
    # dot_general batching stays canonical under vmap+grad (XLA verifier bug
    # workaround, see dryrun notes)
    tv = jnp.matmul(t, rhat[:, None, :, None])[..., 0]      # (E, C, 3)
    out_v = (w[..., 3, None] * s[..., None] * r1
             + w[..., 4, None] * v
             + w[..., 5, None] * jnp.cross(v, jnp.broadcast_to(r1, v.shape))
             + w[..., 6, None] * tv)
    out_t = (w[..., 7, None, None] * s[..., None, None] * Y2
             + w[..., 8, None, None] * t
             + w[..., 9, None, None] * traceless_sym(
                 v[..., :, None] * r1[..., None, :]))
    return {"s": out_s, "v": out_v, "t": out_t}


def self_tensor_product(f, w):
    """Quadratic self-interaction (MACE's A x A): channelwise couplings of a
    feature with itself; w (N, C, 6)."""
    s, v, t = f["s"], f["v"], f["t"]
    out_s = (w[..., 0] * s * s
             + w[..., 1] * jnp.einsum("nci,nci->nc", v, v)
             + w[..., 2] * jnp.einsum("ncij,ncij->nc", t, t))
    out_v = (w[..., 3, None] * jnp.matmul(t, v[..., None])[..., 0])
    out_t = (w[..., 4, None, None] * traceless_sym(
                 v[..., :, None] * v[..., None, :])
             + w[..., 5, None, None] * traceless_sym(jnp.matmul(t, t)))
    return {"s": out_s, "v": out_v, "t": out_t}


def linear_mix(f, ws, wv, wt):
    """Per-irrep channel mixing (self-interaction): w*: (C_in, C_out).
    tensordot+moveaxis (not einsum) keeps dot_general batching canonical
    under vmap+grad (XLA verifier workaround, see dryrun notes)."""
    v = jnp.moveaxis(jnp.tensordot(f["v"], wv, axes=[[-2], [0]]), -1, -2)
    t = jnp.moveaxis(jnp.tensordot(f["t"], wt, axes=[[-3], [0]]), -1, -3)
    return {"s": f["s"] @ ws, "v": v, "t": t}


def gate(f, gv, gt):
    """Equivariant gate: scalars -> silu; v/t scaled by sigmoid(gate
    scalars).  gv/gt: (C_s, C) projections from scalar channels."""
    s = jax.nn.silu(f["s"])
    gvx = jax.nn.sigmoid(f["s"] @ gv)
    gtx = jax.nn.sigmoid(f["s"] @ gt)
    return {"s": s, "v": f["v"] * gvx[..., None],
            "t": f["t"] * gtx[..., None, None]}


def add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def zeros(n, c, dtype=jnp.float32):
    return {"s": jnp.zeros((n, c), dtype), "v": jnp.zeros((n, c, 3), dtype),
            "t": jnp.zeros((n, c, 3, 3), dtype)}


def scatter_nodes(msg, dst, n, valid=None):
    """Segment-sum each irrep component onto destination nodes."""
    dst = jnp.where(valid, dst, n) if valid is not None else dst

    def red(x):
        z = jnp.zeros((n,) + x.shape[1:], x.dtype)
        m = x if valid is None else jnp.where(
            valid.reshape((-1,) + (1,) * (x.ndim - 1)), x, 0)
        return z.at[dst].add(m, mode="drop")

    return jax.tree.map(red, msg)
