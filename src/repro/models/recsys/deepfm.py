"""DeepFM [arXiv:1703.04247]: FM interaction + deep MLP over shared sparse
feature embeddings (Criteo-style: 13 dense + 26 categorical = 39 fields in
the assigned config).

The embedding lookup is the hot path; tables use repro.sparse.embedding_bag
machinery (jnp.take + segment ops -- JAX has no EmbeddingBag).  Tables are
row-sharded over the full mesh; the FM/MLP tower is data-parallel.
`score_candidates` implements the retrieval_cand shape (1 query vs 10^6
candidate items) as one batched dot, not a loop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Criteo Kaggle per-field vocabulary sizes (public DLRM preprocessing);
# fields 0..12 are dense (bucketised here), 13..38 categorical.
CRITEO_VOCABS = (
    64, 128, 128, 64, 256, 128, 64, 64, 128, 16, 32, 64, 64,   # bucketised dense
    1461, 584, 10_131_227, 2_202_608, 306, 25, 12518, 634, 4, 93146,
    5684, 8_351_593, 3195, 28, 14993, 5_461_306, 11, 5653, 2173, 4,
    7_046_547, 18, 16, 286_181, 105, 142_572,
)


@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str
    embed_dim: int = 10
    mlp: tuple = (400, 400, 400)
    vocabs: tuple = CRITEO_VOCABS
    interaction: str = "fm"

    @property
    def n_fields(self) -> int:
        return len(self.vocabs)

    @property
    def total_rows(self) -> int:
        # padded to a multiple of 512 so the row dim shards on any
        # production mesh (256- and 512-chip)
        raw = sum(self.vocabs)
        return ((raw + 511) // 512) * 512


def init_params(cfg: DeepFMConfig, key):
    ks = iter(jax.random.split(key, len(cfg.mlp) + 4))
    d = cfg.embed_dim
    # one concatenated table; per-field row offsets are static
    table = jax.random.normal(next(ks), (cfg.total_rows, d), jnp.float32) * 0.01
    lin = jax.random.normal(next(ks), (cfg.total_rows, 1), jnp.float32) * 0.01
    dims = [cfg.n_fields * d, *cfg.mlp, 1]
    mlp = [jax.random.normal(next(ks), (i, o), jnp.float32) / jnp.sqrt(i)
           for i, o in zip(dims[:-1], dims[1:])]
    return {"table": table, "linear": lin, "mlp": mlp,
            "bias": jnp.zeros(())}


def param_shardings(cfg: DeepFMConfig, *, row_axes=("data", "model")):
    return {"table": P(row_axes, None), "linear": P(row_axes, None),
            "mlp": [P(None, None) for _ in range(len(cfg.mlp) + 1)],
            "bias": P()}


def field_offsets(cfg: DeepFMConfig):
    off = [0]
    for v in cfg.vocabs:
        off.append(off[-1] + v)
    return jnp.asarray(off[:-1], jnp.int32)


def forward(cfg: DeepFMConfig, params, cat_idx):
    """cat_idx: (B, n_fields) per-field categorical ids (within-field).
    Returns logits (B,)."""
    rows = cat_idx + field_offsets(cfg)[None, :]
    emb = jnp.take(params["table"], rows, axis=0)          # (B, F, d)
    lin = jnp.take(params["linear"], rows, axis=0)[..., 0]  # (B, F)

    # FM second-order: 0.5 * ((sum v)^2 - sum v^2), summed over dim
    sv = emb.sum(axis=1)
    fm = 0.5 * (sv**2 - (emb**2).sum(axis=1)).sum(axis=-1)

    h = emb.reshape(emb.shape[0], -1)
    for i, w in enumerate(params["mlp"]):
        h = h @ w
        if i < len(params["mlp"]) - 1:
            h = jax.nn.relu(h)
    return params["bias"] + lin.sum(axis=1) + fm + h[:, 0]


def loss_fn(cfg, params, cat_idx, labels):
    logits = forward(cfg, params, cat_idx)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def score_candidates(cfg: DeepFMConfig, params, user_idx, item_idx):
    """Retrieval scoring: one user (n_user_fields,) against (N, n_item_fields)
    candidates via factored FM cross terms -- O(N d), a batched dot."""
    offs = field_offsets(cfg)
    nu = user_idx.shape[0]
    u_rows = user_idx + offs[:nu]
    i_rows = item_idx + offs[nu:nu + item_idx.shape[1]][None, :]
    ue = jnp.take(params["table"], u_rows, axis=0)          # (Fu, d)
    ie = jnp.take(params["table"], i_rows, axis=0)          # (N, Fi, d)
    ul = jnp.take(params["linear"], u_rows, axis=0).sum()
    il = jnp.take(params["linear"], i_rows, axis=0)[..., 0].sum(-1)
    us, iv = ue.sum(0), ie.sum(1)
    cross = iv @ us                                          # (N,)
    fm_u = 0.5 * ((us**2 - (ue**2).sum(0)).sum())
    fm_i = 0.5 * ((iv**2 - (ie**2).sum(1)).sum(-1))
    return params["bias"] + ul + il + cross + fm_u + fm_i
