"""Shared config plumbing: mesh-axis descriptor + dry-run spec."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: tuple            # data-parallel axes, e.g. ("pod", "data")
    tp: str = "model"    # tensor/expert-parallel axis

    @property
    def all(self):
        return (*self.dp, self.tp)


@dataclasses.dataclass
class DryrunSpec:
    """What dryrun.py lowers: jax.jit(fn, in_shardings, out_shardings)
    .lower(*args).compile()."""
    fn: Callable
    args: tuple                  # ShapeDtypeStructs (pytrees allowed)
    in_shardings: Any
    out_shardings: Any
    static_argnums: tuple = ()
    donate_argnums: tuple = ()
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str
    shapes: tuple
    build_dryrun: Callable        # (shape, mesh, axes: MeshAxes) -> DryrunSpec
    smoke: Callable               # () -> None, raises on failure
    skip_shapes: dict = dataclasses.field(default_factory=dict)
    source: str = ""


def abstract(tree):
    """Pytree -> matching ShapeDtypeStruct pytree."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
