"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d_model=2048 16H
(GQA kv=16) d_ff(expert)=1408 vocab=151936, 60 routed top-4 + 4 shared.
long_500k skipped (pure full attention)."""
import jax.numpy as jnp

from repro.models.lm import LMConfig, MoESettings

CONFIG = LMConfig(
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, d_head=128, d_ff=1408, vocab=151936, rope_theta=1e6,
    moe=MoESettings(n_experts=60, top_k=4, d_ff_expert=1408, n_shared=4,
                    n_experts_padded=64),  # EP divisibility on 16-wide axis
    dtype=jnp.bfloat16)

SKIP_SHAPES = {"long_500k": "pure full attention at every layer"}
