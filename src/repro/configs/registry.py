"""Architecture registry: --arch <id> resolution for dryrun/train/serve."""
from __future__ import annotations

import functools

from repro.configs.common import ArchDef


def _lm(arch_module_name: str):
    import importlib
    mod = importlib.import_module(f"repro.configs.{arch_module_name}")
    from repro.configs import lm_common as LC
    cfg = mod.CONFIG
    return ArchDef(
        arch_id=cfg.name, family="lm",
        shapes=tuple(LC.SHAPES),
        skip_shapes=mod.SKIP_SHAPES,
        build_dryrun=functools.partial(LC.build_lm_dryrun, cfg),
        smoke=functools.partial(LC.smoke_lm, cfg),
        source=mod.__doc__.split("\n")[0])


def _make_archs():
    from repro.configs import gnn_common as GC
    from repro.configs import recsys_common as RC
    from repro.configs import bfs_rmat as BF
    import repro.configs.nequip as nq
    import repro.configs.mace as mc
    import repro.configs.graphsage_reddit as gs
    import repro.configs.egnn as eg
    import repro.configs.deepfm as df

    archs = {}
    for m in ("kimi_k2_1t_a32b", "qwen2_moe_a2_7b", "glm4_9b", "gemma2_2b",
              "h2o_danube_1_8b"):
        a = _lm(m)
        archs[a.arch_id] = a

    archs["nequip"] = ArchDef(
        "nequip", "gnn", tuple(GC.SHAPES),
        functools.partial(GC.build_equiv_dryrun, nq.CONFIG),
        functools.partial(GC.smoke_equiv, 1), nq.SKIP_SHAPES,
        nq.__doc__.split("\n")[0])
    archs["mace"] = ArchDef(
        "mace", "gnn", tuple(GC.SHAPES),
        functools.partial(GC.build_equiv_dryrun, mc.CONFIG),
        functools.partial(GC.smoke_equiv, 3), mc.SKIP_SHAPES,
        mc.__doc__.split("\n")[0])
    archs["graphsage-reddit"] = ArchDef(
        "graphsage-reddit", "gnn", tuple(GC.SHAPES),
        functools.partial(GC.build_sage_dryrun, gs.CONFIG),
        GC.smoke_sage, gs.SKIP_SHAPES, gs.__doc__.split("\n")[0])
    archs["egnn"] = ArchDef(
        "egnn", "gnn", tuple(GC.SHAPES),
        functools.partial(GC.build_egnn_dryrun, eg.CONFIG),
        GC.smoke_egnn, eg.SKIP_SHAPES, eg.__doc__.split("\n")[0])
    archs["deepfm"] = ArchDef(
        "deepfm", "recsys", tuple(RC.SHAPES),
        functools.partial(RC.build_deepfm_dryrun, df.CONFIG),
        RC.smoke_deepfm, df.SKIP_SHAPES, df.__doc__.split("\n")[0])
    archs["bfs-rmat"] = ArchDef(
        "bfs-rmat", "bfs", tuple(BF.SHAPES),
        functools.partial(BF.build_bfs_dryrun, None),
        BF.smoke_bfs, BF.SKIP_SHAPES, BF.__doc__.split("\n")[0])
    return archs


ARCHS = _make_archs()


def get_arch(arch_id: str) -> ArchDef:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]
