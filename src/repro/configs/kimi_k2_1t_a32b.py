"""kimi-k2-1t-a32b [arXiv:2501.kimi2; unverified]: 61L d_model=7168 64H
(GQA kv=8) d_ff(expert)=2048 vocab=163840, MoE 384 experts top-8 (+1 shared).
Trillion-parameter MoE; long_500k skipped (pure full attention)."""
import jax.numpy as jnp

from repro.models.lm import LMConfig, MoESettings

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
    n_kv_heads=8, d_head=128, d_ff=2048, vocab=163840, rope_theta=5e4,
    moe=MoESettings(n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1),
    dtype=jnp.bfloat16)

SKIP_SHAPES = {"long_500k": "pure full attention at every layer (524k-token "
                            "decode assigned only to sub-quadratic archs)"}
