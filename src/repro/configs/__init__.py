from repro.configs.registry import ARCHS, get_arch, MeshAxes, DryrunSpec
