from repro.configs.registry import ARCHS, get_arch
from repro.configs.common import MeshAxes, DryrunSpec
