"""Shared builders for the five LM architectures.

Shapes (assignment):
  train_4k     seq 4096,  global_batch 256   -> train_step (loss+grad+adamw)
  prefill_32k  seq 32768, global_batch 32    -> forward (logits)
  decode_32k   seq 32768 KV cache, batch 128 -> serve_step (1 new token)
  long_500k    seq 524288 KV cache, batch 1  -> serve_step; ONLY for archs
               with a sub-quadratic (sliding-window) component.

Sharding: batch over dp axes; TP/EP over `model`; decode caches shard batch
over dp and heads over model when divisible, long-context caches shard the
SEQUENCE over everything (GSPMD inserts the partial-softmax reductions --
flash-decoding's split-KV as a sharding choice)."""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.common import DryrunSpec, MeshAxes
from repro.models import lm as L
from repro.models.moe import MoEShard
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig, make_train_step, init_state

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _moe_shard(cfg: L.LMConfig, mesh, axes: MeshAxes, variant=None):
    if cfg.moe is None:
        return None
    v = variant or {}
    return MoEShard(mesh=mesh,
                    token_axes=tuple(v.get("token_axes", axes.all)),
                    expert_axis=axes.tp,
                    fsdp_axis=v.get("moe_fsdp_axis"),
                    quant_dispatch=v.get("moe_quant", False))


def _ns(mesh, *parts):
    return NamedSharding(mesh, P(*parts))


def _cache_shardings(cfg, mesh, axes: MeshAxes, batch, long: bool):
    dp = tuple(axes.dp)
    if long:
        # batch=1: shard the cache SEQUENCE over every axis
        kv = _ns(mesh, None, None, (*dp, axes.tp), None, None)
        pos = _ns(mesh, None, None, (*dp, axes.tp))
    else:
        kv = _ns(mesh, None, dp, None, None, None)
        pos = _ns(mesh, None, dp, None)
    return {"k": kv, "v": kv, "pos": pos}


def build_lm_dryrun(cfg: L.LMConfig, shape: str, mesh, axes: MeshAxes,
                    train_cfg: TrainConfig | None = None,
                    variant: dict | None = None) -> DryrunSpec:
    """variant (hillclimb knobs): moe_fsdp_axis, moe_quant, token_axes,
    capacity_factor, microbatches, remat, cache_seq_shard."""
    v = variant or {}
    import dataclasses as _dc
    if v.get("capacity_factor") and cfg.moe:
        cfg = _dc.replace(cfg, moe=_dc.replace(
            cfg.moe, capacity_factor=v["capacity_factor"]))
    if "remat" in v:
        cfg = _dc.replace(cfg, remat=v["remat"])
    sh = SHAPES[shape]
    dp = tuple(axes.dp)
    pspec = L.param_shardings(cfg, model_axis=axes.tp)
    if v.get("moe_fsdp_axis") and cfg.moe:
        fa = v["moe_fsdp_axis"]
        pspec["mlp"]["w1"] = P(None, axes.tp, fa, None)
        pspec["mlp"]["w3"] = P(None, axes.tp, fa, None)
        pspec["mlp"]["w2"] = P(None, axes.tp, None, fa)
    pshard = jax.tree.map(lambda s: _ns(mesh, *s), pspec,
                          is_leaf=lambda s: isinstance(s, P))
    params_abs = jax.eval_shape(lambda k: L.init_params(cfg, k),
                                jax.random.key(0))
    mshard = _moe_shard(cfg, mesh, axes, v)

    if sh["kind"] == "train":
        tc = train_cfg or TrainConfig(optimizer=AdamWConfig(),
                                      microbatches=v.get("microbatches", 1))
        loss = lambda p, b: L.loss_fn(cfg, p, b["tokens"], b["labels"],
                                      mesh=mshard)
        step = make_train_step(loss, tc)
        state_abs = jax.eval_shape(
            lambda p: init_state(tc, p).tree(), params_abs)
        # ZeRO-1: optimizer moments additionally shard their largest
        # divisible unsharded dim over the innermost dp axis
        data_size = mesh.devices.shape[mesh.axis_names.index(dp[-1])]

        def zero_spec(spec, leaf):
            parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
            used = set()
            for p_ in parts:
                for a in (p_ if isinstance(p_, tuple) else (p_,)):
                    used.add(a)
            if dp[-1] in used:          # already FSDP-sharded on data
                return _ns(mesh, *parts)
            for i, (p_, s_) in enumerate(zip(parts, leaf.shape)):
                if p_ is None and s_ % data_size == 0 and s_ >= data_size:
                    parts[i] = dp[-1]
                    break
            return _ns(mesh, *parts)

        mu_shard = jax.tree.map(zero_spec, pspec, params_abs,
                                is_leaf=lambda s: isinstance(s, P))
        opt_shard = {"mu": mu_shard, "nu": mu_shard, "step": _ns(mesh)}
        st_shard = {"params": pshard, "opt": opt_shard, "err": None}
        if tc.microbatches > 1:
            mb = tc.microbatches
            bs = (mb, sh["batch"] // mb, sh["seq"])
            batch_abs = {"tokens": jax.ShapeDtypeStruct(bs, jnp.int32),
                         "labels": jax.ShapeDtypeStruct(bs, jnp.int32)}
            bshard = {"tokens": _ns(mesh, None, dp, None),
                      "labels": _ns(mesh, None, dp, None)}
        else:
            batch_abs = {
                "tokens": jax.ShapeDtypeStruct((sh["batch"], sh["seq"]), jnp.int32),
                "labels": jax.ShapeDtypeStruct((sh["batch"], sh["seq"]), jnp.int32)}
            bshard = {"tokens": _ns(mesh, dp, None), "labels": _ns(mesh, dp, None)}
        return DryrunSpec(fn=step, args=(state_abs, batch_abs),
                          in_shardings=(st_shard, bshard),
                          out_shardings=(st_shard, None),
                          donate_argnums=(0,),
                          note=f"train_step bs={sh['batch']} seq={sh['seq']}")

    if sh["kind"] == "prefill":
        fwd = lambda p, t: L.forward(cfg, p, t, mesh=mshard)[0]
        toks = jax.ShapeDtypeStruct((sh["batch"], sh["seq"]), jnp.int32)
        return DryrunSpec(fn=fwd, args=(params_abs, toks),
                          in_shardings=(pshard, _ns(mesh, dp, None)),
                          out_shardings=_ns(mesh, dp, None, axes.tp),
                          note=f"prefill bs={sh['batch']} seq={sh['seq']}")

    # decode
    long = sh["seq"] > 100_000 or v.get("cache_seq_shard", False)
    cache = jax.eval_shape(
        lambda: L.init_cache(cfg, sh["batch"], sh["seq"]))
    cshard = _cache_shardings(cfg, mesh, axes, sh["batch"], long)
    step = lambda p, c, t, pos: L.decode_step(cfg, p, c, t, pos, mesh=mshard)
    toks = jax.ShapeDtypeStruct((sh["batch"],), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tshard = _ns(mesh, dp) if sh["batch"] >= 8 else _ns(mesh)
    return DryrunSpec(fn=step, args=(params_abs, cache, toks, pos),
                      in_shardings=(pshard, cshard, tshard, _ns(mesh)),
                      out_shardings=(tshard, cshard),
                      donate_argnums=(1,),
                      note=f"decode bs={sh['batch']} kv={sh['seq']}"
                           f"{' seq-sharded-cache' if long else ''}")


def smoke_lm(cfg: L.LMConfig):
    """Reduced-config forward + train step on CPU: shapes + finiteness."""
    import numpy as np
    small = L.LMConfig(
        name=cfg.name + "-smoke", n_layers=2, d_model=64,
        n_heads=min(4, cfg.n_heads), n_kv_heads=min(2, cfg.n_kv_heads),
        d_head=16, d_ff=128, vocab=256, rope_fraction=cfg.rope_fraction,
        attn_softcap=cfg.attn_softcap, logit_softcap=cfg.logit_softcap,
        window_pattern=tuple(min(w, 8) for w in cfg.window_pattern),
        post_norms=cfg.post_norms, tie_embeddings=cfg.tie_embeddings,
        moe=None if cfg.moe is None else L.MoESettings(
            n_experts=8, top_k=min(2, cfg.moe.top_k), d_ff_expert=32,
            n_shared=min(1, cfg.moe.n_shared)),
        dtype=jnp.float32, remat=False)
    p = L.init_params(small, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, small.vocab)
    logits, _ = L.forward(small, p, toks)
    assert logits.shape == (2, 16, small.vocab)
    assert np.isfinite(np.asarray(logits)).all(), "NaN in smoke forward"
    loss, grads = jax.value_and_grad(
        lambda p: L.loss_fn(small, p, toks, toks))(p)
    assert np.isfinite(float(loss))
    # one decode step
    cache = L.init_cache(small, 2, 32)
    nxt, cache = L.decode_step(small, p, cache, toks[:, 0], jnp.int32(0))
    assert nxt.shape == (2,)
