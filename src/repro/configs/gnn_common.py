"""Shared builders for the four GNN architectures.

Shapes (assignment):
  full_graph_sm   n=2,708  e=10,556  d_feat=1,433   (Cora-size full batch)
  minibatch_lg    n=232,965 e=114.6M batch=1,024 fanout 15-10 (Reddit-size,
                  REAL neighbour sampler feeds static blocks)
  ogb_products    n=2,449,029 e=61.86M d_feat=100   (full-batch large)
  molecule        n=30 e=64 batch=128               (batched small graphs)

Distribution: full-graph aggregation for graphsage runs on the paper's 2D
expand/fold SpMM (repro.core.spmm2d) -- the adjacency is partitioned exactly
like the BFS.  Equivariant nets (positions + messages along edge vectors) use
edge-sharded segment ops under GSPMD; citation-graph shapes synthesise
positions/species for them (the shapes, not the semantics, are the assigned
quantity -- see DESIGN.md sec. 6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.common import DryrunSpec, MeshAxes
from repro.core.types import Grid2D
from repro.dist.compat import shard_map
from repro.models.gnn import graphsage as GS
from repro.models.gnn import egnn as EG
from repro.models.gnn import equivariant as EQ

SHAPES = {
    "full_graph_sm": dict(kind="full", n=2708, e=10556, d_feat=1433,
                          classes=7),
    "minibatch_lg": dict(kind="block", n=232965, e=114_615_892,
                         batch=1024, fanout=(15, 10), d_feat=602, classes=41),
    "ogb_products": dict(kind="full", n=2_449_029, e=61_859_140, d_feat=100,
                         classes=47),
    "molecule": dict(kind="mol", n=30, e=64, batch=128),
}


def _ns(mesh, *parts):
    return NamedSharding(mesh, P(*parts))


def _edges_abs(e):
    return (jax.ShapeDtypeStruct((e,), jnp.int32),
            jax.ShapeDtypeStruct((e,), jnp.int32))


# ---------------------------------------------------------------------------
# graphsage
# ---------------------------------------------------------------------------

def build_sage_dryrun(cfg: GS.SAGEConfig, shape, mesh, axes: MeshAxes):
    sh = SHAPES[shape]
    dp = tuple(axes.dp)
    allax = (*dp, axes.tp)

    if sh["kind"] == "block":
        # sampled minibatch: data-parallel over seeds
        B, (f1, f2) = sh["batch"], sh["fanout"]
        c2 = GS.SAGEConfig(cfg.name, cfg.n_layers, cfg.d_hidden,
                           sh["d_feat"], sh["classes"], cfg.aggregator)
        params = jax.eval_shape(lambda k: GS.init_params(c2, k),
                                jax.random.key(0))
        feats = [jax.ShapeDtypeStruct((B, sh["d_feat"]), jnp.float32),
                 jax.ShapeDtypeStruct((B * f1, sh["d_feat"]), jnp.float32),
                 jax.ShapeDtypeStruct((B * f1 * f2, sh["d_feat"]), jnp.float32)]
        labels = jax.ShapeDtypeStruct((B,), jnp.int32)

        def loss_fn(p, bf, lab):
            logits = GS.apply_block(c2, p, bf, [f1, f2])
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, lab[:, None], 1)[:, 0]
            return (lse - ll).mean()

        def step(p, bf, lab):
            return jax.value_and_grad(loss_fn)(p, bf, lab)

        psh = jax.tree.map(lambda _: _ns(mesh), params)
        fsh = [_ns(mesh, dp, None)] * 3
        return DryrunSpec(fn=step, args=(params, feats, labels),
                          in_shardings=(psh, fsh, _ns(mesh, dp)),
                          out_shardings=None,
                          note=f"sampled block B={B} fanout={f1}x{f2}")

    if sh["kind"] == "full":
        # full-graph on the paper's 2D partition (spmm2d expand/fold)
        from repro.core.spmm2d import spmm2d_device
        R = 1
        for a in dp:
            R *= mesh.devices.shape[mesh.axis_names.index(a)]
        C = mesh.devices.shape[mesh.axis_names.index(axes.tp)]
        grid = Grid2D.for_vertices(sh["n"], R, C)
        e_max = int(sh["e"] / (R * C) * 1.5) + 64
        c2 = GS.SAGEConfig(cfg.name, cfg.n_layers, cfg.d_hidden,
                           sh["d_feat"], sh["classes"], cfg.aggregator)
        params = jax.eval_shape(lambda k: GS.init_params(c2, k),
                                jax.random.key(0))
        col_off = jax.ShapeDtypeStruct((R, C, grid.n_cols_local + 1), jnp.int32)
        row_idx = jax.ShapeDtypeStruct((R, C, e_max), jnp.int32)
        feats = jax.ShapeDtypeStruct((grid.n, sh["d_feat"]), jnp.float32)
        labels = jax.ShapeDtypeStruct((grid.n,), jnp.int32)
        dev = P(dp, axes.tp)
        xspec = P((axes.tp, *dp))

        def loss_fn(p, co, ri, x, lab):
            def spmm_shard(h):
                from repro.core.types import LocalGraph2D
                g = LocalGraph2D(col_off=co[0, 0], row_idx=ri[0, 0],
                                 nnz=jnp.int32(0))
                return spmm2d_device(g, h, grid=grid, row_axes=dp,
                                     col_axes=(axes.tp,))
            # one shard_map over the whole model: x enters block-sharded
            def body(co, ri, x, lab):
                h = x
                for lp in p["layers"]:
                    def spmm(hh):
                        from repro.core.types import LocalGraph2D
                        g = LocalGraph2D(col_off=co[0, 0], row_idx=ri[0, 0],
                                         nnz=jnp.int32(0))
                        return spmm2d_device(g, hh, grid=grid, row_axes=dp,
                                             col_axes=(axes.tp,))
                    h = jax.nn.relu(h @ lp["w_self"] + spmm(h) @ lp["w_neigh"])
                logits = h @ p["out"]
                lse = jax.nn.logsumexp(logits, -1)
                ll = jnp.take_along_axis(logits, lab[:, None], 1)[:, 0]
                return jax.lax.pmean((lse - ll).mean(), (*dp, axes.tp))[None]

            out = shard_map(
                body, mesh=mesh,
                in_specs=(dev, dev, xspec, xspec),
                out_specs=P((*dp, axes.tp)), check_vma=False)(co, ri, x, lab)
            return out.sum() / (R * C)

        def step(p, co, ri, x, lab):
            return jax.value_and_grad(loss_fn)(p, co, ri, x, lab)

        pshard = jax.tree.map(lambda _: _ns(mesh), params)
        return DryrunSpec(
            fn=step, args=(params, col_off, row_idx, feats, labels),
            in_shardings=(pshard, _ns(mesh, dp, axes.tp, None),
                          _ns(mesh, dp, axes.tp, None),
                          _ns(mesh, (axes.tp, *dp), None),
                          _ns(mesh, (axes.tp, *dp))),
            out_shardings=None,
            note=f"full-graph 2D expand/fold SpMM n={sh['n']} e={sh['e']}")

    # molecule: SAGE over batched small dense graphs (vmap); positions are
    # ignored by SAGE (feature-only model)
    c3 = GS.SAGEConfig(cfg.name, cfg.n_layers, cfg.d_hidden, 16, 8)
    return _molecule_dryrun_generic(
        lambda key: GS.init_params(c3, key),
        lambda p, f, pos, es, ed: GS.apply_fullgraph(c3, p, f, es, ed).sum(),
        mesh, axes, feat_dim=16)


def _molecule_dryrun_generic(init_fn, energy_fn, mesh, axes, *, feat_dim=None,
                             with_species=False):
    sh = SHAPES["molecule"]
    B, n, e = sh["batch"], sh["n"], sh["e"]
    dp = tuple(axes.dp)
    params = jax.eval_shape(init_fn, jax.random.key(0))
    pos = jax.ShapeDtypeStruct((B, n, 3), jnp.float32)
    es = jax.ShapeDtypeStruct((B, e), jnp.int32)
    ed = jax.ShapeDtypeStruct((B, e), jnp.int32)
    tgt = jax.ShapeDtypeStruct((B,), jnp.float32)

    if with_species:
        extra = jax.ShapeDtypeStruct((B, n), jnp.int32)
    else:
        extra = jax.ShapeDtypeStruct((B, n, feat_dim), jnp.float32)

    def loss(p, x, pos, es, ed, tgt):
        en = jax.vmap(lambda x_, po_, s_, d_:
                      energy_fn(p, x_, po_, s_, d_))(x, pos, es, ed)
        return jnp.mean((en - tgt) ** 2)

    def step(p, x, pos, es, ed, tgt):
        return jax.value_and_grad(loss)(p, x, pos, es, ed, tgt)

    pshard = jax.tree.map(lambda _: _ns(mesh), params)
    bsh = _ns(mesh, dp)
    return DryrunSpec(
        fn=step, args=(params, extra, pos, es, ed, tgt),
        in_shardings=(pshard, _ns(mesh, dp, None) if with_species
                      else _ns(mesh, dp, None, None),
                      _ns(mesh, dp, None, None), _ns(mesh, dp, None),
                      _ns(mesh, dp, None), bsh),
        out_shardings=None, note=f"molecule batch={B}")


# ---------------------------------------------------------------------------
# equivariant (nequip / mace) + egnn
# ---------------------------------------------------------------------------

def build_equiv_dryrun(cfg: EQ.EquivConfig, shape, mesh, axes: MeshAxes):
    sh = SHAPES[shape]
    dp = tuple(axes.dp)
    allax = (*dp, axes.tp)

    if sh["kind"] == "mol":
        return _molecule_dryrun_generic(
            lambda key: EQ.init_params(cfg, key),
            lambda p, sp, pos, es, ed: EQ.apply(cfg, p, sp, pos, es, ed)[0],
            mesh, axes, with_species=True)

    # full / block shapes: synthesized positions + species over the graph's
    # node/edge counts; edge arrays sharded over ALL devices, nodes replicated
    # for small graphs / dp-sharded scatter for large (GSPMD chooses comms).
    n = sh["n"] if sh["kind"] == "full" else sh["batch"] * (
        1 + sh["fanout"][0] + sh["fanout"][0] * sh["fanout"][1])
    e = sh["e"] if sh["kind"] == "full" else n * 8
    e = ((e + 511) // 512) * 512   # edge padding: shardable on 256/512 chips
    params = jax.eval_shape(lambda k: EQ.init_params(cfg, k),
                            jax.random.key(0))
    spec_a = jax.ShapeDtypeStruct((n,), jnp.int32)
    pos = jax.ShapeDtypeStruct((n, 3), jnp.float32)
    es = jax.ShapeDtypeStruct((e,), jnp.int32)
    ed = jax.ShapeDtypeStruct((e,), jnp.int32)
    tgt = jax.ShapeDtypeStruct((n,), jnp.float32)

    def loss(p, sp, pos, es, ed, tgt):
        _, node_e = EQ.apply(cfg, p, sp, pos, es, ed)
        return jnp.mean((node_e - tgt) ** 2)

    def step(p, sp, pos, es, ed, tgt):
        return jax.value_and_grad(loss)(p, sp, pos, es, ed, tgt)

    pshard = jax.tree.map(lambda _: _ns(mesh), params)
    nsh = _ns(mesh, None)
    esh = _ns(mesh, allax)
    return DryrunSpec(
        fn=step, args=(params, spec_a, pos, es, ed, tgt),
        in_shardings=(pshard, nsh, _ns(mesh, None, None), esh, esh, nsh),
        out_shardings=None,
        note=f"{sh['kind']} n={n} e={e} edge-sharded")


def build_egnn_dryrun(cfg: EG.EGNNConfig, shape, mesh, axes: MeshAxes):
    sh = SHAPES[shape]
    dp = tuple(axes.dp)
    allax = (*dp, axes.tp)

    if sh["kind"] == "mol":
        return _molecule_dryrun_generic(
            lambda key: EG.init_params(cfg, key),
            lambda p, f, pos, es, ed: EG.apply(cfg, p, f, pos, es, ed)[0],
            mesh, axes, feat_dim=cfg.d_in)

    n = sh["n"] if sh["kind"] == "full" else sh["batch"] * (
        1 + sh["fanout"][0] + sh["fanout"][0] * sh["fanout"][1])
    e = sh["e"] if sh["kind"] == "full" else n * 8
    e = ((e + 511) // 512) * 512   # edge padding: shardable on 256/512 chips
    params = jax.eval_shape(lambda k: EG.init_params(cfg, k),
                            jax.random.key(0))
    feats = jax.ShapeDtypeStruct((n, cfg.d_in), jnp.float32)
    pos = jax.ShapeDtypeStruct((n, 3), jnp.float32)
    es = jax.ShapeDtypeStruct((e,), jnp.int32)
    ed = jax.ShapeDtypeStruct((e,), jnp.int32)
    tgt = jax.ShapeDtypeStruct((n,), jnp.float32)

    def loss(p, f, pos, es, ed, tgt):
        _, h, _ = EG.apply(cfg, p, f, pos, es, ed)
        return jnp.mean((h[:, 0] - tgt) ** 2)

    def step(p, f, pos, es, ed, tgt):
        return jax.value_and_grad(loss)(p, f, pos, es, ed, tgt)

    pshard = jax.tree.map(lambda _: _ns(mesh), params)
    esh = _ns(mesh, allax)
    return DryrunSpec(
        fn=step, args=(params, feats, pos, es, ed, tgt),
        in_shardings=(pshard, _ns(mesh, None, None), _ns(mesh, None, None),
                      esh, esh, _ns(mesh, None)),
        out_shardings=None, note=f"{sh['kind']} n={n} e={e} edge-sharded")


# ---------------------------------------------------------------------------
# smokes
# ---------------------------------------------------------------------------

def smoke_sage():
    import numpy as np
    from repro.graphgen import rmat_edges
    cfg = GS.SAGEConfig("sage-smoke", 2, 16, 8, 5)
    p = GS.init_params(cfg, jax.random.key(0))
    e = rmat_edges(jax.random.key(1), 7, 4)
    x = jax.random.normal(jax.random.key(2), (128, 8))
    lab = jax.random.randint(jax.random.key(3), (128,), 0, 5)
    loss = GS.loss_fn(cfg, p, x, e[0], e[1], lab)
    assert np.isfinite(float(loss))


def smoke_equiv(corr):
    import numpy as np
    cfg = EQ.EquivConfig("eq-smoke", 2, 8, 4, 2.5, correlation_order=corr)
    p = EQ.init_params(cfg, jax.random.key(0))
    pos = jax.random.normal(jax.random.key(1), (10, 3))
    sp = jax.random.randint(jax.random.key(2), (10,), 0, 8)
    src = jnp.arange(10, dtype=jnp.int32)
    dst = (src + 1) % 10
    en, node_e = EQ.apply(cfg, p, sp, pos, src, dst)
    assert np.isfinite(float(en)) and node_e.shape == (10,)


def smoke_egnn():
    import numpy as np
    cfg = EG.EGNNConfig("egnn-smoke", 2, 16, 4)
    p = EG.init_params(cfg, jax.random.key(0))
    pos = jax.random.normal(jax.random.key(1), (10, 3))
    f = jax.random.normal(jax.random.key(2), (10, 4))
    src = jnp.arange(10, dtype=jnp.int32)
    dst = (src + 1) % 10
    en, h, x = EG.apply(cfg, p, f, pos, src, dst)
    assert np.isfinite(float(en)) and x.shape == (10, 3)
