"""h2o-danube-1.8b [arXiv:2401.16818; hf]: 24L d_model=2560 32H (GQA kv=8)
d_ff=6912 vocab=32000; llama+mistral mix with sliding-window attention
(window 4096 on every layer) -> ring-buffer KV cache, runs long_500k."""
import jax.numpy as jnp

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="h2o-danube-1.8b", n_layers=24, d_model=2560, n_heads=32,
    n_kv_heads=8, d_head=80, d_ff=6912, vocab=32000, rope_theta=1e4,
    window_pattern=(4096,), dtype=jnp.bfloat16)

SKIP_SHAPES = {}
