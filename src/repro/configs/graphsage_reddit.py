"""graphsage-reddit [arXiv:1706.02216]: 2 layers d_hidden=128 mean
aggregator, sample sizes 25-10 (training uses the shape's fanout 15-10 for
minibatch_lg, per the assignment)."""
from repro.models.gnn.graphsage import SAGEConfig

CONFIG = SAGEConfig(name="graphsage-reddit", n_layers=2, d_hidden=128,
                    d_in=602, n_classes=41, aggregator="mean")
SAMPLE_SIZES = (25, 10)
SKIP_SHAPES = {}
