"""DeepFM dry-run builders.

Shapes (assignment):
  train_batch     batch=65,536      train step (loss+grad+adamw)
  serve_p99       batch=512         online scoring
  serve_bulk      batch=262,144     offline scoring
  retrieval_cand  1 query x 1,000,000 candidates (batched dot, no loop)

Embedding tables: row-sharded over the whole mesh (the 33.8M x 10 table);
GSPMD lowers the sharded-row take to masked local gathers + an all-reduce --
the distributed-embedding analog of the paper's fold exchange.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.common import DryrunSpec, MeshAxes
from repro.models.recsys import deepfm as D
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig, make_train_step, init_state

SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=1_000_000),
}

N_USER_FIELDS = 26  # first 26 fields describe the user/context in retrieval


def _ns(mesh, *parts):
    return NamedSharding(mesh, P(*parts))


def build_deepfm_dryrun(cfg: D.DeepFMConfig, shape, mesh, axes: MeshAxes):
    sh = SHAPES[shape]
    dp = tuple(axes.dp)
    allax = (*dp, axes.tp)
    params_abs = jax.eval_shape(lambda k: D.init_params(cfg, k),
                                jax.random.key(0))
    pshard = {"table": _ns(mesh, allax, None),
              "linear": _ns(mesh, allax, None),
              "mlp": [_ns(mesh, None, None) for _ in params_abs["mlp"]],
              "bias": _ns(mesh)}

    if sh["kind"] == "train":
        tc = TrainConfig(optimizer=AdamWConfig())
        loss = lambda p, b: D.loss_fn(cfg, p, b["idx"], b["y"])
        step = make_train_step(loss, tc)
        state_abs = jax.eval_shape(lambda p: init_state(tc, p).tree(),
                                   params_abs)
        st_shard = {"params": pshard,
                    "opt": {"mu": pshard, "nu": pshard, "step": _ns(mesh)},
                    "err": None}
        batch = {"idx": jax.ShapeDtypeStruct((sh["batch"], cfg.n_fields),
                                             jnp.int32),
                 "y": jax.ShapeDtypeStruct((sh["batch"],), jnp.float32)}
        bshard = {"idx": _ns(mesh, dp, None), "y": _ns(mesh, dp)}
        return DryrunSpec(fn=step, args=(state_abs, batch),
                          in_shardings=(st_shard, bshard),
                          out_shardings=(st_shard, None),
                          donate_argnums=(0,),
                          note=f"train batch={sh['batch']}")

    if sh["kind"] == "serve":
        fwd = lambda p, idx: D.forward(cfg, p, idx)
        idx = jax.ShapeDtypeStruct((sh["batch"], cfg.n_fields), jnp.int32)
        bshard = _ns(mesh, dp, None) if sh["batch"] >= 512 else _ns(mesh, None, None)
        return DryrunSpec(fn=fwd, args=(params_abs, idx),
                          in_shardings=(pshard, bshard),
                          out_shardings=_ns(mesh, dp) if sh["batch"] >= 512
                          else _ns(mesh),
                          note=f"serve batch={sh['batch']}")

    # retrieval: 1 user x n_cand items, candidates sharded over all devices
    # (padded up to a multiple of 512 so the candidate dim shards)
    n_item = cfg.n_fields - N_USER_FIELDS
    n_cand = ((sh["n_cand"] + 511) // 512) * 512
    user = jax.ShapeDtypeStruct((N_USER_FIELDS,), jnp.int32)
    items = jax.ShapeDtypeStruct((n_cand, n_item), jnp.int32)
    fn = lambda p, u, it: D.score_candidates(cfg, p, u, it)
    return DryrunSpec(fn=fn, args=(params_abs, user, items),
                      in_shardings=(pshard, _ns(mesh, None),
                                    _ns(mesh, allax, None)),
                      out_shardings=_ns(mesh, allax),
                      note=f"retrieval n_cand={sh['n_cand']}")


def smoke_deepfm():
    import numpy as np
    cfg = D.DeepFMConfig(name="deepfm-smoke", embed_dim=4, mlp=(16, 16),
                         vocabs=(8, 16, 32, 8))
    p = D.init_params(cfg, jax.random.key(0))
    idx = jax.random.randint(jax.random.key(1), (8, 4), 0, 8)
    y = (jax.random.uniform(jax.random.key(2), (8,)) > 0.5).astype(jnp.float32)
    loss, g = jax.value_and_grad(lambda p: D.loss_fn(cfg, p, idx, y))(p)
    assert np.isfinite(float(loss))
    s = D.score_candidates(cfg, p, jnp.asarray([1, 2], jnp.int32),
                           jax.random.randint(jax.random.key(3), (50, 2), 0, 8))
    assert s.shape == (50,) and np.isfinite(np.asarray(s)).all()
