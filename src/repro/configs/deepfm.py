"""deepfm [arXiv:1703.04247]: 39 sparse fields, embed_dim=10,
MLP 400-400-400, FM interaction; Criteo-style vocabularies (~33.8M rows)."""
from repro.models.recsys.deepfm import DeepFMConfig

CONFIG = DeepFMConfig(name="deepfm", embed_dim=10, mlp=(400, 400, 400))
SKIP_SHAPES = {}
