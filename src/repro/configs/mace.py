"""mace [arXiv:2206.07697]: n_layers=2 d_hidden=128 l_max=2
correlation_order=3 n_rbf=8, higher-order (ACE) equivariant message passing
via repeated self-tensor-products."""
from repro.models.gnn.equivariant import EquivConfig

CONFIG = EquivConfig(name="mace", n_layers=2, d_hidden=128, n_rbf=8,
                     cutoff=5.0, correlation_order=3)
SKIP_SHAPES = {}
