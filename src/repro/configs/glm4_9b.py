"""glm4-9b [hf:THUDM/glm-4-9b]: 40L d_model=4096 32H (GQA kv=2)
d_ff=13696 vocab=151552, RoPE with partial rotary factor 0.5.
long_500k skipped (pure full attention)."""
import jax.numpy as jnp

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="glm4-9b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_head=128, d_ff=13696, vocab=151552, rope_theta=1e4, rope_fraction=0.5,
    dtype=jnp.bfloat16)

SKIP_SHAPES = {"long_500k": "pure full attention at every layer"}
