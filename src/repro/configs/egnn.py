"""egnn [arXiv:2102.09844]: 4 layers d_hidden=64, E(n)-equivariant."""
from repro.models.gnn.egnn import EGNNConfig

CONFIG = EGNNConfig(name="egnn", n_layers=4, d_hidden=64, d_in=8)
SKIP_SHAPES = {}
