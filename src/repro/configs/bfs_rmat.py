"""The paper's own workload: distributed BFS on R-MAT graphs (Table 1).

Dry-run lowers the WHOLE search program (BFS2D's while_loop over levels:
expand all_gather -> column scan -> fold all_to_all -> update, + the final
deferred-predecessor exchange) at the Table-1 scale for the mesh size:
256 GPUs -> scale 29, 512 -> scale 30, edge factor 16.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.common import DryrunSpec, MeshAxes
from repro.core.bfs2d import BFS2D
from repro.core.types import Grid2D

# paper Table 1: #GPUs -> (grid, scale)
TABLE1 = {1: ((1, 1), 21), 2: ((1, 2), 22), 4: ((2, 2), 23), 8: ((2, 4), 24),
          16: ((4, 4), 25), 32: ((4, 8), 26), 64: ((8, 8), 27),
          128: ((8, 16), 28), 256: ((16, 16), 29), 512: ((16, 32), 30),
          1024: ((32, 32), 31), 2048: ((32, 64), 32), 4096: ((64, 64), 33)}
EDGE_FACTOR = 16

SHAPES = {"rmat_weak": dict(kind="bfs")}
SKIP_SHAPES = {}


def build_bfs_dryrun(_cfg, shape, mesh, axes: MeshAxes):
    n_dev = mesh.devices.size
    _, scale = TABLE1[n_dev]
    R = 1
    for a in axes.dp:
        R *= mesh.devices.shape[mesh.axis_names.index(a)]
    C = mesh.devices.shape[mesh.axis_names.index(axes.tp)]
    n = 1 << scale
    grid = Grid2D.for_vertices(n, R, C)
    # undirected doubling: 2 * ef * n directed edges; 1.5x padding for skew
    e_max = int(2 * EDGE_FACTOR * n / (R * C) * 1.5)
    bfs = BFS2D(grid, mesh, row_axes=axes.dp, col_axes=(axes.tp,),
                edge_chunk=1 << 20)
    col_off = jax.ShapeDtypeStruct((R, C, grid.n_cols_local + 1), jnp.int32)
    row_idx = jax.ShapeDtypeStruct((R, C, e_max), jnp.int32)
    nnz = jax.ShapeDtypeStruct((R, C), jnp.int32)
    root = jax.ShapeDtypeStruct((), jnp.int32)
    return DryrunSpec(fn=bfs._run, args=(col_off, row_idx, nnz, root),
                      in_shardings=None, out_shardings=None,
                      note=f"full BFS scale={scale} grid={R}x{C} "
                           f"e_max/dev={e_max}")


def smoke_bfs():
    import numpy as np
    from repro.dist.compat import make_mesh
    from repro.graphgen import rmat_edges, build_csc
    from repro.core import bfs_reference_py, partition_2d
    from repro.core.types import LocalGraph2D
    n = 1 << 7
    edges = rmat_edges(jax.random.key(0), 7, 6)
    mesh = make_mesh((1, 1), ("r", "c"))
    grid = Grid2D.for_vertices(n, 1, 1)
    lg = partition_2d(np.asarray(edges), grid)
    bfs = BFS2D(grid, mesh, edge_chunk=256)
    out = bfs.run(LocalGraph2D(jnp.asarray(lg.col_off),
                               jnp.asarray(lg.row_idx), jnp.asarray(lg.nnz)), 3)
    co, ri = build_csc(edges, n)
    ref, _ = bfs_reference_py(co, ri, 3, n)
    assert (np.asarray(out.level)[:n] == ref).all()
