"""gemma2-2b [arXiv:2408.00118; hf]: 26L d_model=2304 8H (GQA kv=4)
d_ff=9216 vocab=256000; alternating local(4096)/global attention, attn
softcap 50, final-logit softcap 30, sandwich norms, tied embeddings,
query scale 1/sqrt(256).  Runs long_500k (hybrid local/global)."""
import jax.numpy as jnp

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="gemma2-2b", n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_head=256, d_ff=9216, vocab=256000, rope_theta=1e4,
    attn_softcap=50.0, logit_softcap=30.0, query_scale=256**-0.5,
    window_pattern=(4096, 0), post_norms=True, tie_embeddings=True,
    dtype=jnp.bfloat16)

SKIP_SHAPES = {}
