"""nequip [arXiv:2101.03164]: n_layers=5 d_hidden=32 l_max=2 n_rbf=8
cutoff=5, E(3) tensor-product message passing (Cartesian l<=2 basis here;
DESIGN.md sec. 3)."""
from repro.models.gnn.equivariant import EquivConfig

CONFIG = EquivConfig(name="nequip", n_layers=5, d_hidden=32, n_rbf=8,
                     cutoff=5.0, correlation_order=1)
SKIP_SHAPES = {}
