"""Quickstart: generate an R-MAT graph, run BFS, validate, report TEPS.

    PYTHONPATH=src python examples/quickstart.py [scale] [edge_factor]
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.graphgen import rmat_edges, build_csc
from repro.core import bfs_single, validate_bfs
from repro.core.validate import count_component_edges, harmonic_mean


def main(scale=14, ef=16, n_roots=8):
    n = 1 << scale
    print(f"generating R-MAT scale={scale} ef={ef} "
          f"({ef * n:,} input edges)...")
    edges = rmat_edges(jax.random.key(1), scale, ef)
    co, ri = build_csc(edges, n)
    edges_np = np.asarray(edges)

    deg = np.bincount(edges_np[0], minlength=n)
    roots = np.random.default_rng(0).choice(np.flatnonzero(deg > 0),
                                            n_roots, replace=False)
    # warmup/compile
    lvl, pred = bfs_single(co, ri, int(roots[0]))
    jax.block_until_ready(lvl)

    teps = []
    for root in roots:
        t0 = time.perf_counter()
        lvl, pred = bfs_single(co, ri, int(root))
        jax.block_until_ready(lvl)
        dt = time.perf_counter() - t0
        validate_bfs(edges_np, np.asarray(lvl), np.asarray(pred), int(root))
        m = count_component_edges(edges_np, np.asarray(lvl))
        teps.append(m / dt)
        print(f"  root={int(root):7d} levels={int(lvl.max())} "
              f"visited={(np.asarray(lvl) >= 0).sum():8,} "
              f"TEPS={m / dt:.3e}  [validated]")
    print(f"harmonic mean TEPS over {n_roots} roots: "
          f"{harmonic_mean(teps):.3e}")


if __name__ == "__main__":
    main(*(int(a) for a in sys.argv[1:]))
