"""GraphSAGE full-graph training where the neighbour aggregation runs on the
paper's 2D expand/fold pattern (repro.core.spmm2d) over a 2x2 device grid --
the BFS communication schedule as a GNN training substrate.

    python examples/gnn_fullgraph_2d.py
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges
from repro.core import Grid2D, partition_2d
from repro.core.spmm2d import make_spmm2d
from repro.core.types import LocalGraph2D
from repro.models.gnn import graphsage as GS


def main():
    R = C = 2
    scale, d_in, classes = 10, 16, 5
    n = 1 << scale
    mesh = make_mesh((R, C), ("r", "c"))
    grid = Grid2D.for_vertices(n, R, C)
    edges = rmat_edges(jax.random.key(0), scale, 8)
    lg = partition_2d(np.asarray(edges), grid)
    graph = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                         jnp.asarray(lg.nnz))
    spmm = make_spmm2d(grid, mesh)

    # learnable task: labels = argmax over class-prototype features of the
    # aggregated neighbourhood (so aggregation actually matters)
    key = jax.random.key(1)
    feats = jax.random.normal(key, (grid.n, d_in))
    agg0 = spmm(graph.col_off, graph.row_idx, graph.nnz, feats)
    proto = jax.random.normal(jax.random.key(2), (d_in, classes))
    labels = jnp.argmax(agg0 @ proto, -1)

    cfg = GS.SAGEConfig("sage-2d", 2, 32, d_in, classes)
    params = GS.init_params(cfg, jax.random.key(3))

    def loss_fn(p):
        h = feats
        for lp in p["layers"]:
            agg = spmm(graph.col_off, graph.row_idx, graph.nnz, h)
            h = jax.nn.relu(h @ lp["w_self"] + agg @ lp["w_neigh"])
        logits = h @ p["out"]
        lse = jax.nn.logsumexp(logits, -1)
        ll = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
        return (lse - ll).mean()

    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
    oc = AdamWConfig(lr=5e-3, weight_decay=0.0, warmup_steps=0,
                     total_steps=10_000, grad_clip=1.0)
    opt = adamw_init(params)
    vg = jax.jit(jax.value_and_grad(loss_fn))
    first = None
    for i in range(80):
        loss, g = vg(params)
        params, opt, _ = adamw_update(oc, params, g, opt)
        first = first if first is not None else float(loss)
        if i % 10 == 0:
            print(f"step {i:3d} loss={float(loss):.4f}")
    final = float(vg(params)[0])
    print(f"loss {first:.3f} -> {final:.4f} "
          f"({'learning works' if final < 0.5 * first else 'unexpected'})")


if __name__ == "__main__":
    main()
