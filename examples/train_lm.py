"""End-to-end LM training: a reduced gemma2-style model on the synthetic
token pipeline for a few hundred steps, with checkpointing + fault-tolerant
step runner.  Loss must drop (the pipeline has learnable copy structure).

    PYTHONPATH=src python examples/train_lm.py [steps] [--full-100m]
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.models import lm as L
from repro.optim.adamw import AdamWConfig
from repro.train import TrainConfig, make_train_step
from repro.train.train_step import init_state
from repro.data import synthetic_lm_batches
from repro.ckpt import CheckpointManager
from repro.runtime import StepRunner, RetryPolicy


def main(steps=200, full=False):
    if full:  # ~100M params (for real hardware; slow on 1 CPU core)
        cfg = L.LMConfig(name="train-100m", n_layers=12, d_model=768,
                         n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048,
                         vocab=32000, window_pattern=(1024, 0),
                         tie_embeddings=True, dtype=jnp.float32, remat=False)
        batch, seq = 8, 512
    else:
        cfg = L.LMConfig(name="train-mini", n_layers=4, d_model=256,
                         n_heads=8, n_kv_heads=4, d_head=32, d_ff=1024,
                         vocab=512, window_pattern=(64, 0),
                         tie_embeddings=True, dtype=jnp.float32, remat=False)
        batch, seq = 16, 128
    n_params = cfg.param_count()
    print(f"{cfg.name}: {n_params / 1e6:.1f}M params, "
          f"batch={batch} seq={seq}, {steps} steps")

    params = L.init_params(cfg, jax.random.key(0))
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-4, warmup_steps=20,
                                           total_steps=steps))
    step = jax.jit(make_train_step(
        lambda p, b: L.loss_fn(cfg, p, b[0], b[1]), tc))
    state = init_state(tc, params).tree()

    ckpt = CheckpointManager("ckpt_train_lm", keep=2)
    runner = StepRunner(step, policy=RetryPolicy(), ckpt=ckpt, ckpt_every=100)

    data = synthetic_lm_batches(cfg.vocab, batch, seq, n_batches=steps)
    losses = []
    t0 = time.time()
    for i, (toks, labels) in enumerate(data):
        state, info = step(state, (jnp.asarray(toks), jnp.asarray(labels)))
        if i % 20 == 0 or i == steps - 1:
            l = float(info["loss"])
            losses.append(l)
            print(f"step {i:4d} loss={l:.4f} "
                  f"gnorm={float(info['grad_norm']):.3f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
        if i % 100 == 0:
            ckpt.save(i, state)
    ckpt.wait()
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'IMPROVED' if losses[-1] < losses[0] - 0.2 else 'check config'})")


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200
    main(steps, full="--full-100m" in sys.argv)
