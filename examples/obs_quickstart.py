"""Telemetry quickstart (DESIGN.md sec. 13): a traced BFS printing the
per-level LevelTrace table, then a small served run dumping the request's
span lifecycle, the Prometheus exposition and the event-log tail.

    PYTHONPATH=src python examples/obs_quickstart.py [scale] [edge_factor]

Single-process, single-device (grid 1x1) so it runs anywhere; the trace
carry and the serve spans are identical on a real mesh -- see
benchmarks/workers/trace_worker.py for the 2x2 multi-device driver.
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.graphgen import rmat_edges
from repro.serve import GraphServer, ServeConfig


def main(scale=12, ef=8):
    n = 1 << scale
    edges = np.asarray(rmat_edges(jax.random.key(42), scale, ef))
    config = BFSConfig(grid=(1, 1), edge_chunk=16384, telemetry=True)
    graph = DistGraph.from_edges(edges, config, n=n)
    deg = np.bincount(edges[0], minlength=n)
    roots = np.flatnonzero(deg > 0)[:32:4].astype(np.int32)

    # --- layer 1: the in-program per-level trace ---------------------------
    sess = graph.session()
    out = sess.bfs(int(roots[0]))
    trace = sess.last_trace()           # also out.trace
    print(f"BFS from root {int(roots[0])}: {int(out.n_levels)} levels, "
          f"{out.edges_scanned} edges scanned")
    print(f"{'level':>5} {'frontier':>9} {'scanned':>9} {'folded':>7} "
          f"{'wire_B':>7} {'dir':>4}")
    for row in trace.levels():
        print(f"{row['level']:>5} {row['frontier']:>9} {row['scanned']:>9} "
              f"{row['folded']:>7} {row['wire_bytes']:>7} {row['dir']:>4}")
    assert trace.total_scanned == out.edges_scanned

    # --- layers 2+3: the server's registry, spans and event log ------------
    with GraphServer({"g": graph},
                     ServeConfig(max_batch=4, window_s=0.01)) as server:
        tickets = [server.bfs("g", int(r), tenant=("alice", "bob")[i % 2])
                   for i, r in enumerate(roots[:6])]
        results = [t.result(timeout=300) for t in tickets]
        assert all(r.ok for r in results)

        r0 = results[0]
        print(f"\nrequest seq={r0.seq} spans "
              f"(batch of {r0.batch_size}, padded to {r0.padded_to}):")
        for span in r0.trace.spans:
            print(f"  {span.name:>9} {span.dur_s * 1e3:8.2f} ms")

        print("\nPrometheus exposition (first 12 lines):")
        for line in server.prometheus().splitlines()[:12]:
            print(f"  {line}")

        print("\nevent-log tail:")
        for event in server.events.tail(3):
            print(f"  {event}")
    print("\nOK")


if __name__ == "__main__":
    main(*(int(a) for a in sys.argv[1:3]))
