"""Serve quickstart: two resident graphs behind one GraphServer, mixed
BFS/SSSP traffic from two tenants, coalesced by continuous batching
(DESIGN.md sec. 12).

    PYTHONPATH=src python examples/serve_quickstart.py [scale] [edge_factor]

Single-process, single-device (grid 1x1) so it runs anywhere; the serving
layer is identical on a real mesh -- see benchmarks/workers/serve_worker.py
for the 2x2 multi-device load generator.
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.graphgen import rmat_edges
from repro.serve import GraphServer, ServeConfig


def main(scale=12, ef=8):
    config = BFSConfig(grid=(1, 1), edge_chunk=16384)

    def plan(s, seed):
        edges = np.asarray(rmat_edges(jax.random.key(seed), s, ef))
        w = ((np.abs(edges[0] * 31 + edges[1]) % 254) + 1).astype(np.uint8)
        g = DistGraph.from_edges(edges, config, n=1 << s, weights=w)
        deg = np.bincount(edges[0], minlength=1 << s)
        return g, np.flatnonzero(deg > 0)[:32:4].astype(np.int32)

    print(f"planning two graphs (scale {scale} and {scale - 1})...")
    (g_web, roots_web), (g_road, roots_road) = plan(scale, 1), \
        plan(scale - 1, 2)

    with GraphServer({"web": g_web, "road": g_road},
                     ServeConfig(max_batch=8, window_s=0.01)) as server:
        server.warm(("bfs", "sssp"))
        print(f"serving {server.graphs}; submitting mixed traffic...")

        tickets = []
        for i in range(8):       # alice: BFS on the web graph (coalesces)
            tickets.append(("bfs", server.bfs(
                "web", int(roots_web[i]), tenant="alice")))
        for i in range(4):       # bob: SSSP on the road graph
            tickets.append(("sssp", server.sssp(
                "road", int(roots_road[i]), tenant="bob")))
        server.drain()

        for program, ticket in tickets:
            res = ticket.result(timeout=60)
            assert res.ok, res.error
            reached = int((np.asarray(
                res.value.level if program == "bfs" else res.value.dist)
                >= 0).sum())
            print(f"  {res.tenant:5s} {program:4s} on {res.graph:4s}: "
                  f"reached {reached:6,} vertices in a batch of "
                  f"{res.batch_size} (padded to {res.padded_to}), "
                  f"queued {res.queued_s * 1e3:5.1f} ms")

        stats = server.metrics_snapshot()
        occ = stats["mean_occupancy"]
        print(f"batches: {stats['n_batches']}  mean occupancy: {occ:.2f}  "
              f"pad waste: {stats['pad_waste_frac']:.0%}")
        print(f"aot cache: {stats['aot_cache']}")
        for tenant, s in sorted(stats["tenants"].items()):
            print(f"  tenant {tenant}: {s['queries']} queries, "
                  f"{s['edges_scanned']:,} edges scanned")
        assert occ and occ > 1, "expected coalescing (occupancy > 1)"
        print("OK (coalesced; every result bit-identical to a direct "
              "session call)")


if __name__ == "__main__":
    main(*(int(a) for a in sys.argv[1:]))
