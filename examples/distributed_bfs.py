"""END-TO-END DRIVER (the paper's workload): distributed 2D-partitioned BFS
over an R x C device grid, Graph500-style -- 64 searches from random roots,
validated output, harmonic-mean TEPS (paper sec. 4).

Uses the session API (DESIGN.md sec. 7): plan the graph into residency once
with `DistGraph.from_edges`, then answer many queries with
`GraphSession.bfs` -- per root, and the whole sweep batched as ONE compiled
program.

    python examples/distributed_bfs.py [R] [C] [scale] [ef] [n_roots] [fold]

fold in {list, bitmap, delta} picks the fold wire codec (DESIGN.md sec. 4).
Runs on forced host devices (R*C); on a real TPU pod the same code runs with
row_axes/col_axes bound to the pod mesh (see repro/launch/bfs_run.py).
"""
import os
import sys

R = int(sys.argv[1]) if len(sys.argv) > 1 else 2
C = int(sys.argv[2]) if len(sys.argv) > 2 else 4
SCALE = int(sys.argv[3]) if len(sys.argv) > 3 else 14
EF = int(sys.argv[4]) if len(sys.argv) > 4 else 16
N_ROOTS = int(sys.argv[5]) if len(sys.argv) > 5 else 64
FOLD = sys.argv[6] if len(sys.argv) > 6 else "list"

os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={R * C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.core import validate_bfs
from repro.core.validate import count_component_edges, harmonic_mean
from repro.graphgen import rmat_edges


def main():
    n = 1 << SCALE
    print(f"grid {R}x{C} | R-MAT scale={SCALE} ef={EF} | {N_ROOTS} roots")
    edges_np = np.asarray(rmat_edges(jax.random.key(1), SCALE, EF))

    # phase 1: plan once -- partition + device placement, resident thereafter
    t0 = time.perf_counter()
    graph = DistGraph.from_edges(
        edges_np, BFSConfig(grid=(R, C), fold_codec=FOLD, edge_chunk=16384),
        n=n)
    print(f"2D partition in {time.perf_counter() - t0:.1f}s "
          f"(max {int(np.asarray(graph.csc.nnz).max()):,} edges/device)")

    # phase 2: query -- many searches against the resident graph
    session = graph.session()
    deg = np.bincount(edges_np[0], minlength=n)
    roots = np.random.default_rng(7).choice(np.flatnonzero(deg > 0),
                                            N_ROOTS, replace=False)
    out = session.bfs(int(roots[0]))
    jax.block_until_ready(out.level)  # compile once (B=1 program)

    teps, validated = [], 0
    for i, root in enumerate(roots):
        t0 = time.perf_counter()
        out = session.bfs(int(root))
        jax.block_until_ready(out.level)
        dt = time.perf_counter() - t0
        lvl = np.asarray(out.level)[:n]
        m = count_component_edges(edges_np, lvl)
        teps.append(m / dt)
        if i < 8:  # validate a subset (validation is python-side O(E))
            validate_bfs(edges_np, lvl, np.asarray(out.pred)[:n], int(root))
            validated += 1
    print(f"harmonic mean TEPS: {harmonic_mean(teps):.3e} "
          f"({validated} searches fully validated)")

    # the same sweep as ONE compiled program (amortised Graph500 view)
    jax.block_until_ready(session.bfs(roots).level)  # compile once (B=N)
    t0 = time.perf_counter()
    bout = session.bfs(roots)
    jax.block_until_ready(bout.level)
    sweep_s = time.perf_counter() - t0
    swept = sum(count_component_edges(edges_np, np.asarray(bout.level[b])[:n])
                for b in range(N_ROOTS))
    print(f"batched {N_ROOTS}-root sweep: {sweep_s:.3f}s, "
          f"amortised {swept / sweep_s:.3e} TEPS")


if __name__ == "__main__":
    main()
