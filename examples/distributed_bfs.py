"""END-TO-END DRIVER (the paper's workload): distributed 2D-partitioned BFS
over an R x C device grid, Graph500-style -- 64 searches from random roots,
validated output, harmonic-mean TEPS (paper sec. 4).

    python examples/distributed_bfs.py [R] [C] [scale] [ef] [n_roots] [fold]

fold in {list, bitmap, delta} picks the fold wire codec (DESIGN.md sec. 4).
Runs on forced host devices (R*C); on a real TPU pod the same code runs with
row_axes/col_axes bound to the pod mesh (see repro/launch/bfs_run.py).
"""
import os
import sys

R = int(sys.argv[1]) if len(sys.argv) > 1 else 2
C = int(sys.argv[2]) if len(sys.argv) > 2 else 4
SCALE = int(sys.argv[3]) if len(sys.argv) > 3 else 14
EF = int(sys.argv[4]) if len(sys.argv) > 4 else 16
N_ROOTS = int(sys.argv[5]) if len(sys.argv) > 5 else 64
FOLD = sys.argv[6] if len(sys.argv) > 6 else "list"

os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={R * C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges
from repro.core import Grid2D, partition_2d, validate_bfs
from repro.core.bfs2d import BFS2D
from repro.core.types import LocalGraph2D
from repro.core.validate import count_component_edges, harmonic_mean


def main():
    n = 1 << SCALE
    print(f"grid {R}x{C} | R-MAT scale={SCALE} ef={EF} | {N_ROOTS} roots")
    edges = rmat_edges(jax.random.key(1), SCALE, EF)
    edges_np = np.asarray(edges)

    t0 = time.perf_counter()
    mesh = make_mesh((R, C), ("r", "c"))
    grid = Grid2D.for_vertices(n, R, C)
    lg = partition_2d(edges_np, grid)
    graph = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                         jnp.asarray(lg.nnz))
    print(f"2D partition in {time.perf_counter() - t0:.1f}s "
          f"(max {int(lg.nnz.max()):,} edges/device)")

    bfs = BFS2D(grid, mesh, edge_chunk=16384, fold_codec=FOLD)
    deg = np.bincount(edges_np[0], minlength=n)
    roots = np.random.default_rng(7).choice(np.flatnonzero(deg > 0),
                                            N_ROOTS, replace=False)
    out = bfs.run(graph, int(roots[0]))
    jax.block_until_ready(out.level)  # compile once

    teps, validated = [], 0
    for i, root in enumerate(roots):
        t0 = time.perf_counter()
        out = bfs.run(graph, int(root))
        jax.block_until_ready(out.level)
        dt = time.perf_counter() - t0
        lvl = np.asarray(out.level)[:n]
        m = count_component_edges(edges_np, lvl)
        teps.append(m / dt)
        if i < 8:  # validate a subset (validation is python-side O(E))
            validate_bfs(edges_np, lvl, np.asarray(out.pred)[:n], int(root))
            validated += 1
    print(f"harmonic mean TEPS: {harmonic_mean(teps):.3e} "
          f"({validated} searches fully validated)")


if __name__ == "__main__":
    main()
